"""Compacted solve substrate property suite.

Fuzzes the global<->local bijection of ``core.compact.CompactedView``
end to end: round-trip identity, equivalence of view-compacted solves
with the legacy masked-subgraph solves, residual *write-through*
conservation (locally-sized placers re-assemble the global network
exactly), view invalidation on churn, and the empty-region error paths
the regional plane guards against.
"""
import numpy as np
import pytest

from repro.core import (
    CompactedView,
    DataflowPath,
    OnlinePlacer,
    compact_view,
    random_dataflow,
    region_line,
    solve,
    solve_batch,
    waxman,
)
from repro.core.problem import stack_requests
from repro.service import (
    RegionalControlPlane,
    partition_regions,
    region_subgraph,
    validate_region_of,
)

PYM = dict(method="leastcost_python")


# ---------------------------------------------------------------------------
# bijection round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bijection_round_trip_fuzz(seed):
    rng = np.random.default_rng(seed)
    rg = waxman(10 + 2 * seed, seed=seed)
    R = int(rng.integers(2, 5))
    assign = partition_regions(rg, R, seed=seed)
    covered = np.zeros(rg.n, bool)
    for r in range(R):
        v = compact_view(rg, assign, r)
        members = np.nonzero(assign == r)[0]
        # local -> global -> local is the identity on the local space
        loc = np.arange(v.n_local)
        np.testing.assert_array_equal(v.to_local(v.to_global(loc)), loc)
        # global -> local -> global is the identity on the member set
        np.testing.assert_array_equal(v.to_global(v.to_local(members)), members)
        assert all(v.contains(int(g)) for g in members)
        covered[members] = True
        # foreign ids raise, never mask
        foreign = np.nonzero(assign != r)[0]
        if foreign.size:
            with pytest.raises(ValueError):
                v.to_local(int(foreign[0]))
        # df round trip re-pins endpoints and shares the requirements
        df = DataflowPath.make([0.1, 0.2], [1.0],
                               int(members[0]), int(members[-1]))
        ldf = v.compact_df(df)
        rdf = v.uncompact_df(ldf)
        assert (rdf.src, rdf.dst) == (df.src, df.dst)
        assert ldf.creq is df.creq and ldf.breq is df.breq
    assert covered.all()  # views partition the node set


def test_compact_graph_slices_match_masked_subgraph():
    rg = waxman(14, seed=3)
    assign = partition_regions(rg, 3, seed=1)
    for r in range(3):
        v = compact_view(rg, assign, r)
        sub = region_subgraph(rg, assign, r)  # masked, global ids
        g = v.graph()
        assert g.n == v.n_local == int(np.sum(assign == r))
        ix = np.ix_(v.nodes, v.nodes)
        np.testing.assert_array_equal(g.cap, sub.cap[v.nodes])
        np.testing.assert_array_equal(g.bw, sub.bw[ix])
        np.testing.assert_array_equal(g.lat, sub.lat[ix])


def test_identity_view_translations_return_same_objects():
    """The R=1 bit-identity hook: the identity view never copies."""
    rg = waxman(9, seed=0)
    v = CompactedView.identity(rg)
    assert v.is_identity
    assert v.graph() is rg and v.compact_graph(rg) is rg
    df = random_dataflow(rg, 3, seed=1)
    assert v.compact_df(df) is df and v.uncompact_df(df) is df
    m, _ = solve(rg, df, **PYM)
    if m is not None:
        assert v.uncompact_mapping(m) is m and v.compact_mapping(m) is m


def test_empty_region_and_bad_assignment_raise_clear_errors():
    rg = waxman(6, seed=0)
    assign = np.array([0, 0, 0, 2, 2, 2])  # region 1 empty (gap)
    with pytest.raises(ValueError, match="empty"):
        compact_view(rg, assign, 1)
    with pytest.raises(ValueError, match="empty"):
        validate_region_of(rg, assign)
    with pytest.raises(ValueError, match="shape"):
        validate_region_of(rg, [0, 1])
    with pytest.raises(ValueError, match="empty"):
        RegionalControlPlane(rg, regions=3, region_of=assign, **PYM)
    from repro.core import ResourceGraph

    empty = ResourceGraph(np.zeros(0, np.float32),
                          np.zeros((0, 0), np.float32),
                          np.zeros((0, 0), np.float32))
    with pytest.raises(ValueError, match="empty"):
        partition_regions(empty, 2)
    # partition_regions itself never yields an empty region
    for n, R, seed in [(5, 4, 0), (7, 7, 1), (12, 5, 2), (4, 9, 3)]:
        a = partition_regions(waxman(n, seed=seed), R, seed=seed)
        counts = np.bincount(a)
        assert counts.min() >= 1


# ---------------------------------------------------------------------------
# solve equivalence: compacted view vs masked global subgraph
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_solve_through_view_matches_masked_subgraph_solve(seed):
    """engine.solve(view=...) must behave exactly like solving on the
    legacy masked global subgraph, with the mapping lifted back to global
    ids — same feasibility, same cost, same assignment."""
    rg = waxman(15, seed=seed)
    assign = partition_regions(rg, 3, seed=seed)
    rng = np.random.default_rng(seed)
    checked = 0
    for r in range(3):
        v = compact_view(rg, assign, r)
        sub = region_subgraph(rg, assign, r)
        members = np.nonzero(assign == r)[0]
        if members.size < 2:
            continue
        for _ in range(6):
            s, d = rng.choice(members, size=2, replace=False)
            p = int(rng.integers(2, 5))
            creq = rng.uniform(0.05, 0.4, p).astype(np.float32)
            breq = rng.uniform(0.5, 3.0, p - 1).astype(np.float32)
            df = DataflowPath(creq, breq, int(s), int(d))
            mv, stv = solve(rg, df, view=v, **PYM)
            mm, stm = solve(sub, df, **PYM)
            assert (mv is None) == (mm is None)
            assert stv.solve_n == v.n_local and stm.solve_n == rg.n
            if mv is not None:
                assert mv.assign == mm.assign and mv.route == mm.route
                assert mv.cost == pytest.approx(mm.cost)
                checked += 1
    assert checked >= 3  # the fuzz actually exercised feasible solves


def test_solve_batch_through_view_lifts_all_mappings():
    rg = waxman(12, seed=4)
    assign = partition_regions(rg, 2, seed=0)
    v = compact_view(rg, assign, 0)
    members = np.nonzero(assign == 0)[0]
    dfs = [
        DataflowPath.make([0.0, 0.2, 0.0], [1.0, 1.0],
                          int(members[i]), int(members[-1 - i]))
        for i in range(2)
    ]
    ms_v, st = solve_batch(rg, dfs, view=v, **PYM)
    sub = region_subgraph(rg, assign, 0)
    ms_m, _ = solve_batch(sub, dfs, **PYM)
    assert st.solve_n == v.n_local
    for a, b in zip(ms_v, ms_m):
        assert (a is None) == (b is None)
        if a is not None:
            assert a.assign == b.assign and a.cost == pytest.approx(b.cost)


def test_view_aware_tensors_pad_to_local_n():
    """The DP/kernel tensor stack built through a view is n_r-sized —
    the VMEM/HBM footprint claim of the compacted substrate."""
    rg = waxman(16, seed=2)
    assign = partition_regions(rg, 4, seed=0)
    v = compact_view(rg, assign, 0)
    members = np.nonzero(assign == 0)[0]
    df = DataflowPath.make([0.0, 0.1, 0.0], [1.0, 1.0],
                           int(members[0]), int(members[-1]))
    tensors, _ = stack_requests(rg, [df], view=v)
    assert tensors["cap"].shape == (v.n_local,)
    assert tensors["bw"].shape == (v.n_local, v.n_local)
    assert tensors["lat"].shape == (v.n_local, v.n_local)
    assert int(tensors["src"][0]) == v.to_local(df.src)


# ---------------------------------------------------------------------------
# residual write-through conservation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_write_through_conservation_fuzz(seed):
    """Locally-sized per-region placers, driven through admit/release
    churn, must re-assemble the *global* base network exactly when their
    residuals and ticket loads are lifted through the views."""
    rg = waxman(14, seed=seed)
    assign = partition_regions(rg, 3, seed=seed)
    views = [compact_view(rg, assign, r) for r in range(3)]
    placers = [OnlinePlacer(rg, view=v, **PYM) for v in views]
    for p, v in zip(placers, views):
        assert p.base.n == v.n_local  # state is locally sized
    rng = np.random.default_rng(seed)
    live: list[tuple[int, int]] = []
    for step in range(40):
        r = int(rng.integers(0, 3))
        members = np.nonzero(assign == r)[0]
        if rng.random() < 0.65 or not live:
            s, d = rng.choice(members, size=2, replace=False)
            p = int(rng.integers(2, 4))
            df = DataflowPath(
                rng.uniform(0.02, 0.2, p).astype(np.float32),
                rng.uniform(0.5, 2.0, p - 1).astype(np.float32),
                int(s), int(d))
            t = placers[r].admit(views[r].compact_df(df))
            if t is not None:
                live.append((r, t.tid))
        else:
            rr, tid = live.pop(int(rng.integers(0, len(live))))
            placers[rr].release(tid)
        # write-through: global residual + global loads == global base
        cap = np.zeros(rg.n)
        bw = np.zeros((rg.n, rg.n))
        in_region = np.zeros((rg.n, rg.n), bool)
        for pl, v in zip(placers, views):
            cap += v.uncompact_node_vec(pl.cap)
            bw += v.uncompact_link_mat(pl.bw)
            in_region |= v.uncompact_link_mat(
                np.ones((v.n_local, v.n_local), bool))
            for t in pl.tickets.values():
                for gv, c in v.uncompact_node_load(t.node_load).items():
                    cap[gv] += c
                for (gu, gv), b in v.uncompact_edge_load(t.edge_load).items():
                    bw[gu, gv] += b
        np.testing.assert_allclose(cap, rg.cap, atol=1e-4)
        np.testing.assert_allclose(bw[in_region], rg.bw[in_region], atol=1e-4)
        for pl in placers:
            pl.check_invariants()
    assert any(pl.stats.admitted for pl in placers)


def test_placer_solve_sizes_are_region_local():
    rg = waxman(20, seed=5)
    assign = partition_regions(rg, 4, seed=1)
    v = compact_view(rg, assign, 0)
    pl = OnlinePlacer(rg, view=v, **PYM)
    members = np.nonzero(assign == 0)[0]
    df = DataflowPath.make([0.0, 0.1], [1.0], int(members[0]), int(members[1]))
    pl.admit(v.compact_df(df))
    assert pl.stats.solves == 1
    assert pl.stats.mean_solve_n == v.n_local  # n_r, not the global 20


# ---------------------------------------------------------------------------
# view invalidation on churn
# ---------------------------------------------------------------------------


def test_view_invalidation_on_churn():
    """Node/link churn bumps the owning region's bijection generation;
    cut-link churn touches no region's slice (broker ledger only)."""
    rg, assign = region_line(3, 4, seed=2)
    cp = RegionalControlPlane(rg, regions=3, region_of=assign, seed=0, **PYM)
    cp.register_tenant("a")
    v0 = [v.version for v in cp.views]
    victim = 1  # in region 0
    r = int(cp.region_of[victim])
    cp.fail_node(victim)
    assert cp.views[r].version == v0[r] + 1
    assert all(cp.views[q].version == v0[q] for q in range(3) if q != r)
    cp.restore_node(victim)
    assert cp.views[r].version == v0[r] + 2
    # in-region link churn invalidates too
    cp.fail_link(0, 1)
    assert cp.views[0].version == v0[0] + 3
    cp.restore_link(0, 1)
    # cut-link churn is broker business: no view generation changes
    before = [v.version for v in cp.views]
    (cut, _) = sorted(cp.cut_base)[0], None
    cp.fail_link(*sorted(cp.cut_base)[0])
    cp.restore_link(*sorted(cp.cut_base)[0])
    assert [v.version for v in cp.views] == before
    cp.check_invariants()


def test_span_parts_record_bijection_version():
    """Spanning reservations carry the generation they were minted under;
    churn elsewhere in the region bumps the view, making staleness
    detectable (version strictly below current)."""
    rg, assign = region_line(2, 4, seed=0)
    cp = RegionalControlPlane(rg, regions=2, region_of=assign, seed=0, **PYM)
    cp.register_tenant("a")
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    rid = cp.submit("a", DataflowPath.make([0.1, 0.1], [1.0], u, v))
    (t,) = cp.pump()
    assert all(
        p.version == cp.views[p.region].version for p in t.parts)
    # churn a non-gateway node in part 0's region: the view generation
    # advances past the part's recorded version
    part = t.parts[0]
    others = [int(g) for g in cp.views[part.region].nodes
              if not cp._span_uses_node(t, int(g))]
    assert others, "need a node the placement does not touch"
    cp.fail_node(others[0])
    assert part.version < cp.views[part.region].version
    assert rid in cp.active_ids()  # untouched placement survived
    cp.check_invariants()


# ---------------------------------------------------------------------------
# nested views (hierarchical planes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_nested_bijection_round_trip_fuzz(seed):
    """A CompactedView of a CompactedView composes to the direct bijection:
    inner-local -> outer-local -> global round-trips exactly, and
    ``compose`` flattens the chain into the single equivalent view."""
    rng = np.random.default_rng(seed)
    rg = waxman(16 + 2 * seed, seed=seed)
    groups = partition_regions(rg, 2, seed=seed)
    outer = compact_view(rg, groups, int(rng.integers(0, 2)))
    # partition the outer view's compacted graph again (ids in [0, n_g))
    inner_assign = partition_regions(outer.graph(), 2, seed=seed + 1)
    for q in range(2):
        inner = outer.derive(np.nonzero(inner_assign == q)[0])
        assert inner._outer is outer and inner in outer._inner
        loc = np.arange(inner.n_local)
        # inner-local -> inner-base(=outer-local) -> global, vs composed
        direct = outer.compose(inner)
        np.testing.assert_array_equal(
            direct.nodes, outer.to_global(inner.to_global(loc)))
        np.testing.assert_array_equal(
            direct.to_global(loc), outer.to_global(inner.to_global(loc)))
        # round trip back down through both levels
        np.testing.assert_array_equal(
            inner.to_local(outer.to_local(direct.to_global(loc))), loc)
        # the composed compacted tensors equal slicing global directly
        g1 = direct.graph()
        g2 = inner.compact_graph(outer.graph())
        np.testing.assert_array_equal(g1.cap, g2.cap)
        np.testing.assert_array_equal(g1.bw, g2.bw)
        np.testing.assert_array_equal(g1.lat, g2.lat)
    # shape mismatches fail fast instead of mistranslating
    with pytest.raises(ValueError, match="cannot adopt"):
        outer.adopt(CompactedView.identity(rg))
    with pytest.raises(ValueError, match="cannot compose"):
        outer.compose(CompactedView.identity(rg))


def test_two_level_write_through_conservation():
    """Leaf placers nested two views deep (global -> group -> leaf) must
    re-assemble the global base exactly when lifted through the COMPOSED
    bijections — conservation survives nesting."""
    rg = waxman(18, seed=3)
    groups = partition_regions(rg, 2, seed=3)
    outers = [compact_view(rg, groups, g) for g in range(2)]
    leaves = []  # (composed view, leaf view, placer)
    for outer in outers:
        inner_assign = partition_regions(outer.graph(), 2, seed=5)
        for q in range(2):
            leaf = outer.derive(np.nonzero(inner_assign == q)[0])
            pl = OnlinePlacer(outer.graph(), view=leaf, **PYM)
            assert pl.base.n == leaf.n_local
            leaves.append((outer.compose(leaf), leaf, pl))
    rng = np.random.default_rng(7)
    for step in range(30):
        cv, leaf, pl = leaves[int(rng.integers(0, len(leaves)))]
        if rng.random() < 0.7 or not pl.tickets:
            if cv.n_local < 2:
                continue
            s, d = rng.choice(cv.n_local, size=2, replace=False)
            p = int(rng.integers(2, 4))
            pl.admit(DataflowPath(
                rng.uniform(0.02, 0.2, p).astype(np.float32),
                rng.uniform(0.5, 2.0, p - 1).astype(np.float32),
                int(s), int(d)))
        else:
            pl.release(next(iter(pl.tickets)))
        cap = np.zeros(rg.n)
        bw = np.zeros((rg.n, rg.n))
        in_region = np.zeros((rg.n, rg.n), bool)
        for cv2, _, pl2 in leaves:
            cap += cv2.uncompact_node_vec(pl2.cap)
            bw += cv2.uncompact_link_mat(pl2.bw)
            in_region |= cv2.uncompact_link_mat(
                np.ones((cv2.n_local, cv2.n_local), bool))
            for t in pl2.tickets.values():
                for gv, c in cv2.uncompact_node_load(t.node_load).items():
                    cap[gv] += c
                for (gu, gv), b in cv2.uncompact_edge_load(
                        t.edge_load).items():
                    bw[gu, gv] += b
        np.testing.assert_allclose(cap, rg.cap, atol=1e-4)
        np.testing.assert_allclose(bw[in_region], rg.bw[in_region], atol=1e-4)
    assert any(pl.stats.admitted for _, _, pl in leaves)


def test_invalidate_propagates_through_derivation_chain():
    """A leaf churn is visible at every enclosing level (ancestors bump);
    an outer invalidation cascades to every descendant; siblings are
    untouched — their slice of truth did not change."""
    rg = waxman(16, seed=6)
    groups = partition_regions(rg, 2, seed=6)
    outer0 = compact_view(rg, groups, 0)
    outer1 = compact_view(rg, groups, 1)
    a0 = partition_regions(outer0.graph(), 2, seed=0)
    leaf00 = outer0.derive(np.nonzero(a0 == 0)[0])
    leaf01 = outer0.derive(np.nonzero(a0 == 1)[0])
    a1 = partition_regions(outer1.graph(), 2, seed=0)
    leaf10 = outer1.derive(np.nonzero(a1 == 0)[0])

    # leaf churn: ancestors bump, siblings (and the other subtree) do not
    leaf00.invalidate()
    assert leaf00.version == 1 and outer0.version == 1
    assert leaf01.version == 0  # sibling untouched
    assert outer1.version == 0 and leaf10.version == 0  # other subtree
    # cached tensors of the invalidated chain were dropped and rebuild
    assert outer0.graph().n == outer0.n_local

    # outer churn: every descendant bumps, the other subtree does not
    outer0.invalidate()
    assert outer0.version == 2
    assert leaf00.version == 2 and leaf01.version == 1
    assert outer1.version == 0 and leaf10.version == 0

    # the regional plane drives this end to end: churn in one leaf region
    # of a hierarchy bumps the enclosing group view automatically
    from repro.core import region_tree
    from repro.service import HierarchicalControlPlane

    trg, assign = region_tree(2, 2, 3, seed=1)
    cp = HierarchicalControlPlane(
        trg, levels=2, region_of=assign, seed=0, **PYM)
    cp.register_tenant("a")
    g = int(cp.group_of[0])
    top0 = cp.views[g].version
    leaf0 = cp.children[g].views[0].version
    other = [v.version for v in cp.views if v is not cp.views[g]]
    cp.fail_node(0)
    assert cp.children[g].views[0].version == leaf0 + 1
    assert cp.views[g].version == top0 + 1  # propagated up
    assert [v.version for v in cp.views if v is not cp.views[g]] == other
    cp.check_invariants()
