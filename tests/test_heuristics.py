"""LeastCostMap (python + tensorized JAX + kernel path), annealed, random-k,
and the distributed simulator — against the exact algorithm."""
import numpy as np
import pytest

from repro.core import (
    SimConfig, anneal_python, leastcost_jax, leastcost_python, pathmap_exact,
    paper_example, random_dataflow, random_k_python, simulate,
    validate_mapping, waxman, barabasi_albert,
)


def _instances(n_graphs=15, n=12, p=5, gen=waxman):
    for seed in range(n_graphs):
        rg = gen(n, seed=seed)
        df = random_dataflow(rg, p, seed=seed + 777)
        yield seed, rg, df


@pytest.mark.parametrize("gen", [waxman, barabasi_albert])
def test_leastcost_feasibility_and_quality(gen):
    """Heuristic never beats the optimum, is always feasible, and matches it
    in the overwhelming majority of instances (paper §3.4.1: ~99%)."""
    opt = tot = 0
    for seed, rg, df in _instances(gen=gen):
        ex, _ = pathmap_exact(rg, df, max_states=300_000)
        for name, (m, stats) in {
            "py": leastcost_python(rg, df),
            "jax": leastcost_jax(rg, df),
        }.items():
            if ex is None:
                assert m is None, (name, seed)
                continue
            if m is not None:
                ok, why = validate_mapping(rg, df, m)
                assert ok, (name, seed, why)
                assert m.cost >= ex.cost - 1e-5, (name, seed)
        if ex is not None:
            tot += 1
            mj, _ = leastcost_jax(rg, df)
            if mj is not None and abs(mj.cost - ex.cost) < 1e-4:
                opt += 1
    assert tot >= 5
    assert opt / tot >= 0.8  # paper reports ~0.99; allow slack on tiny sample


def test_jax_kernel_path_matches_reference():
    for seed, rg, df in _instances(n_graphs=8):
        m1, _ = leastcost_jax(rg, df, use_kernel=False)
        m2, _ = leastcost_jax(rg, df, use_kernel=True)
        assert (m1 is None) == (m2 is None)
        if m1 is not None:
            assert m1.cost == pytest.approx(m2.cost, rel=1e-5)


def test_simulator_policies():
    rg, df = paper_example()
    ex, _ = pathmap_exact(rg, df)
    res = {}
    for pol in ["exact", "leastcost", "annealed", "random_k"]:
        m, st = simulate(rg, df, SimConfig(policy=pol, seed=3, k=2))
        assert m is not None
        ok, why = validate_mapping(rg, df, m)
        assert ok, (pol, why)
        res[pol] = (m.cost, st.messages_sent)
    assert res["exact"][0] == pytest.approx(ex.cost)
    assert res["leastcost"][0] == pytest.approx(ex.cost)
    # the pruned policies send far fewer messages than exhaustive flooding
    # (random_k keeps exact-style state, so it is compared to exact: §3.4.3)
    assert res["leastcost"][1] < res["exact"][1] / 3
    assert res["random_k"][1] < res["exact"][1]


def test_simulator_first_vs_quiesce():
    rg, df = paper_example()
    m1, s1 = simulate(rg, df, SimConfig(policy="leastcost", stop="first"))
    m2, s2 = simulate(rg, df, SimConfig(policy="leastcost", stop="quiesce"))
    assert m1 is not None and m2 is not None
    assert m1.cost >= m2.cost - 1e-9  # early stop may be suboptimal
    assert s1.messages_processed <= s2.messages_processed


def test_annealed_and_random_k_feasible():
    for seed, rg, df in _instances(n_graphs=6):
        for m, _ in (anneal_python(rg, df, seed=seed),
                     random_k_python(rg, df, k=2, seed=seed)):
            if m is not None:
                ok, why = validate_mapping(rg, df, m)
                assert ok, (seed, why)
