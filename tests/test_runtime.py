"""Fault-tolerance runtime: checkpoint/restart, failure injection, straggler
watchdog, elastic resharding — all exercised for real on CPU."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_train_step, init_train_state
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import Trainer, TrainerConfig

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=128, dtype="float32")
SHAPE = ShapeConfig("s", "train", seq_len=16, global_batch=4)


def _mk_trainer(tmp, **kw):
    mesh = make_local_mesh(1, 1)
    built = build_train_step(CFG, SHAPE, mesh,
                             OptConfig(lr=1e-3, warmup_steps=2, total_steps=100),
                             masked=True)
    state = init_train_state(CFG, built)
    data = iter(SyntheticLM(CFG.vocab, SHAPE.seq_len, SHAPE.global_batch, seed=0))
    tc = TrainerConfig(ckpt_dir=str(tmp), ckpt_every=5, async_ckpt=False, **kw)
    return Trainer(tc, state, built.fn, data,
                   state_shardings=built.in_shardings[0]), built


def test_checkpoint_roundtrip(tmp_path):
    tr, built = _mk_trainer(tmp_path)
    tr.run(6)
    step = ckpt.latest_step(str(tmp_path))
    assert step is not None and step >= 5
    restored, s = ckpt.restore(str(tmp_path), tr.state)
    got = jax.tree.leaves(restored)[1]
    want = jax.tree.leaves(jax.tree.map(np.asarray, tr.state))[1]
    # restored leaf matches a saved version of the state (same shapes/dtypes)
    assert got.shape == np.asarray(want).shape


def test_failure_injection_restarts(tmp_path):
    tr, _ = _mk_trainer(tmp_path)
    fired = {"n": 0}

    def boom(step):
        if step == 7 and fired["n"] == 0:
            fired["n"] += 1
            raise RuntimeError("injected node failure")

    tr.inject_failure = boom
    tr.run(10)
    kinds = [e["kind"] for e in tr.events]
    assert "failure" in kinds and "restore" in kinds
    assert tr.restarts == 1
    assert len(tr.metrics_log) >= 10


def test_straggler_watchdog(tmp_path):
    tr, _ = _mk_trainer(tmp_path, straggler_factor=2.5, straggler_window=10)
    slow = {"hit": False}
    orig = tr.step_fn

    def maybe_slow(state, batch):
        if len(tr.step_times) == 8 and not slow["hit"]:
            slow["hit"] = True
            time.sleep(max(0.3, 5 * np.median(tr.step_times)))
        return orig(state, batch)

    tr.step_fn = maybe_slow
    tr.run(12)
    assert any(e["kind"] == "straggler" for e in tr.events)


def test_elastic_reshard(tmp_path):
    """Save under one mesh, restore under a different one (node loss)."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np
        from repro.ckpt import checkpoint as ckpt
        from repro.launch.mesh import make_local_mesh
        from repro.launch.steps import build_train_step, init_train_state
        from repro.models.config import ModelConfig, ShapeConfig
        from repro.optim.adamw import OptConfig
        from repro.models.registry import make_batch
        from repro.dist import sharding as shd

        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab=128,
                          dtype="float32")
        shape = ShapeConfig("s", "train", 16, 4)
        mesh4 = make_local_mesh(4, 1)
        built4 = build_train_step(cfg, shape, mesh4, OptConfig())
        state = init_train_state(cfg, built4)
        batch = make_batch(cfg, shape)
        state, _ = built4.fn(state, batch)
        ckpt.save("{d}", 1, state)

        # "lose" two nodes: restore onto a 2-device mesh
        mesh2 = make_local_mesh(2, 1)
        built2 = build_train_step(cfg, shape, mesh2, OptConfig())
        restored, step = ckpt.restore("{d}", jax.tree.map(np.asarray, state),
                                      sharding_tree=built2.in_shardings[0])
        state2, m = built2.fn(restored, batch)
        assert np.isfinite(m["loss"]), m
        print("ELASTIC_OK", step, float(m["loss"]))
    """).format(d=str(tmp_path / "el"))
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "ELASTIC_OK" in p.stdout, p.stderr[-2000:]


def test_prefetcher():
    it = Prefetcher(iter(SyntheticLM(64, 8, 2, seed=1)), depth=2)
    batches = [next(it) for _ in range(3)]
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    # learnable structure: next token is an affine function within documents
    b = batches[0]
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
