"""Exact PathMap (paper Alg. 1-3) vs an independent brute-force oracle."""
import numpy as np
import pytest

from repro.core import (
    DataflowPath, ResourceGraph, brute_force, pathmap_exact, paper_example,
    random_dataflow, validate_mapping, waxman,
)


def test_paper_example_optimal():
    rg, df = paper_example()
    m, stats = pathmap_exact(rg, df)
    assert m is not None
    ok, why = validate_mapping(rg, df, m)
    assert ok, why
    # the paper's §2.2 optimal mapping: s,x1,x2 -> B, x3 -> D, t -> F
    assert m.cost == pytest.approx(4.0)
    assert m.assign == (1, 1, 1, 3, 5)


@pytest.mark.parametrize("seed", range(25))
def test_exact_matches_brute_force(seed):
    rg = waxman(11, seed=seed)
    df = random_dataflow(rg, 5, seed=seed + 500)
    ex, _ = pathmap_exact(rg, df, max_states=300_000)
    bf = brute_force(rg, df, max_routes=300_000)
    assert (ex is None) == (bf is None)
    if ex is not None:
        assert ex.cost == pytest.approx(bf.cost, rel=1e-5)
        ok, why = validate_mapping(rg, df, ex)
        assert ok, why


def test_find_first_returns_feasible():
    rg, df = paper_example()
    m, _ = pathmap_exact(rg, df, find_first=True)
    assert m is not None
    ok, why = validate_mapping(rg, df, m)
    assert ok, why


def test_infeasible_capacity():
    # no node can host the middle computation
    rg = ResourceGraph.from_edge_list(
        [1.0, 1.0, 1.0], [(0, 1, 100.0, 1.0), (1, 2, 100.0, 1.0)]
    )
    df = DataflowPath.make([0.0, 5.0, 0.0], [10.0, 10.0], src=0, dst=2)
    m, _ = pathmap_exact(rg, df)
    assert m is None


def test_infeasible_bandwidth():
    rg = ResourceGraph.from_edge_list(
        [5.0, 5.0, 5.0], [(0, 1, 5.0, 1.0), (1, 2, 5.0, 1.0)]
    )
    df = DataflowPath.make([0.0, 1.0, 0.0], [10.0, 10.0], src=0, dst=2)
    m, _ = pathmap_exact(rg, df)
    assert m is None


def test_pass_through_hop():
    # dst reachable only through a zero-capacity relay: a dataflow edge must
    # span a multi-hop path (paper §2.1 zero-computation visits)
    rg = ResourceGraph.from_edge_list(
        [5.0, 0.0, 5.0], [(0, 1, 100.0, 1.0), (1, 2, 100.0, 1.0)]
    )
    df = DataflowPath.make([0.0, 2.0, 0.0], [10.0, 10.0], src=0, dst=2)
    m, _ = pathmap_exact(rg, df)
    assert m is not None
    assert m.route == (0, 1, 2)
    assert 1 not in set(m.assign)  # relay hosts nothing
