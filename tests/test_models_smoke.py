"""Per-assigned-architecture smoke tests: reduced config of the same family,
one forward/train step on CPU, asserting output shapes + finite values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_train_step, init_train_state
from repro.models.config import ShapeConfig
from repro.models.registry import init_model, loss_fn, make_batch
from repro.optim.adamw import OptConfig


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    shape = ShapeConfig("smoke", "train", seq_len=32, global_batch=2)
    params, axes = init_model(cfg, jax.random.key(0))
    # axes tree mirrors params
    assert jax.tree.structure(jax.tree.map(lambda _: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda _: 0, axes,
                                        is_leaf=lambda x: isinstance(x, tuple)))
    batch = make_batch(cfg, shape, seed=1)
    loss = loss_fn(cfg)(cfg, params, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # one full train step (adamw) moves the loss
    mesh = make_local_mesh(1, 1)
    built = build_train_step(cfg, shape, mesh,
                             OptConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    state = init_train_state(cfg, built, seed=0)
    state, m1 = built.fn(state, batch)
    state, m2 = built.fn(state, batch)
    assert np.isfinite(m1["loss"]) and np.isfinite(m2["loss"]), arch
    assert m2["loss"] < m1["loss"] + 1.0  # sanity: no explosion


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.family == "encdec":
        from repro.models import encdec as ed
        params, _ = init_model(cfg, jax.random.key(0))
        frames = jnp.zeros((2, 16, cfg.d_model), jnp.float32)
        cache, _ = ed.init_encdec_cache(cfg, 2, 32, 16, jnp.float32)
        cache, _enc = ed.encdec_prefill(cfg, params, frames, cache, remat=False)
        logits, cache = ed.encdec_decode_step(
            cfg, params, jnp.zeros((2, 1), jnp.int32), cache, jnp.int32(0))
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), arch
        return
    from repro.models import transformer as lm
    params, _ = init_model(cfg, jax.random.key(0))
    cache, _ = lm.init_lm_cache(cfg, 2, 32, jnp.float32)
    tokens = jnp.ones((2, 8), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.zeros((2, 4, cfg.d_model), jnp.float32)
    logits, cache = lm.lm_prefill(cfg, params, tokens, cache, **kw)
    assert logits.shape == (2, 1, cfg.vocab)
    logits2, cache = lm.lm_decode_step(
        cfg, params, jnp.ones((2, 1), jnp.int32), cache,
        jnp.int32(8 + (4 if cfg.family == "vlm" else 0)))
    assert logits2.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all()), arch


def test_param_counts_match_names():
    """The exact configs reproduce the published parameter counts."""
    expect = {
        "qwen2.5-14b": (14.0e9, 15.5e9),
        "qwen2-0.5b": (0.4e9, 0.55e9),
        "llama3.2-1b": (1.1e9, 1.4e9),
        "phi3.5-moe-42b-a6.6b": (40e9, 43e9),
        "deepseek-moe-16b": (15.5e9, 17e9),
        "falcon-mamba-7b": (6.8e9, 7.6e9),
        "whisper-medium": (0.7e9, 0.82e9),
        "zamba2-7b": (6.0e9, 7.6e9),
        "stablelm-3b": (2.5e9, 3.1e9),
        "internvl2-2b": (1.7e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    active = get_config("phi3.5-moe-42b-a6.6b").active_param_count()
    assert 6.0e9 <= active <= 7.2e9  # "a6.6b"
