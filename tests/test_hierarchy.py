"""Hierarchical control plane property suite.

The nesting claims, fuzz-enforced: the levels=1 hierarchy is bit-identical
to the flat regional plane (the same composition argument as R=1 vs the
centralized plane, one level up); nested planes keep every level's ticket
ledger, cut conservation and cross-level write-through intact under
adversarial interleavings; spanning decomposition recurses (a top-level
segment may split again inside its child); churn displacement chains
through ``on_broker_displace`` up the tree; gossip is tree-structured
(each level's bus carries at most ``branching`` aggregated records); and
no component's resident state scales with the global plane.
"""
import numpy as np
import pytest

from repro.core import DataflowPath, random_dataflow, region_tree, waxman
from repro.service import (
    ControlPlane,
    FairSharePolicy,
    HierarchicalControlPlane,
    RegionalControlPlane,
    resolve_nesting,
)

PYM = dict(method="leastcost_python")  # pure-python backend: fast, no jit


# ---------------------------------------------------------------------------
# topology generator
# ---------------------------------------------------------------------------


def test_region_tree_generator_shape():
    levels, b, k = 2, 3, 4
    rg, assign = region_tree(levels, b, k, seed=3)
    leaves = b**levels
    assert rg.n == leaves * k
    assert assign.shape == (rg.n,)
    # depth-first leaf numbering: contiguous node blocks per leaf
    np.testing.assert_array_equal(
        assign, np.repeat(np.arange(leaves), k))
    # leaves are fully meshed internally
    for leaf in range(leaves):
        base = leaf * k
        for i in range(k):
            for j in range(i + 1, k):
                assert rg.bw[base + i, base + j] > 0
    # grouping any contiguous block of b^(levels-1) leaves = one subtree;
    # siblings at every level are joined (the quotient graph is connected)
    sub = b ** (levels - 1)
    group_of = assign // sub
    cross = [
        (u, v) for (u, v) in rg.edges() if group_of[u] != group_of[v]
    ]
    assert cross, "top-level siblings must be joined by gateway links"
    # gateway links carry the scaled bandwidth and level-scaled latency
    for (u, v) in cross:
        assert rg.lat[u, v] == pytest.approx(5.0 * levels)
    # every pair of top-level groups is adjacent (all-to-all siblings)
    pairs = {(int(group_of[u]), int(group_of[v])) for (u, v) in cross}
    assert pairs == {(i, j) for i in range(b) for j in range(b) if i != j}


# ---------------------------------------------------------------------------
# construction / facade / fail-fast validation
# ---------------------------------------------------------------------------


def test_facade_dispatches_on_levels():
    rg, assign = region_tree(2, 2, 3, seed=0)
    cp = ControlPlane(rg, levels=2, region_of=assign, **PYM)
    assert isinstance(cp, HierarchicalControlPlane)
    assert cp.levels == 2 and cp.B == 2 and cp.leaf_regions == 4
    assert all(isinstance(c, RegionalControlPlane) for c in cp.children)
    # the solver config must never see the nesting kwargs
    cp.register_tenant("a")
    cp.submit("a", DataflowPath.make([0.0, 0.1], [1.0], 0, 1))
    cp.pump()
    cp.check_invariants()
    # levels=1 on the facade IS the flat plane (same object kind)
    flat = ControlPlane(rg, levels=1, region_of=assign, **PYM)
    assert isinstance(flat, RegionalControlPlane) and flat.R == 4
    # regions= alone resolves branching when it is a perfect power
    cp3 = ControlPlane(rg, levels=2, regions=4, **PYM)
    assert isinstance(cp3, HierarchicalControlPlane) and cp3.B == 2
    # deeper nesting recurses
    rg3, assign3 = region_tree(3, 2, 3, seed=1)
    cp4 = ControlPlane(rg3, levels=3, region_of=assign3, **PYM)
    assert isinstance(cp4, HierarchicalControlPlane)
    assert all(
        isinstance(c, HierarchicalControlPlane) for c in cp4.children)
    assert all(c.levels == 2 for c in cp4.children)


def test_nesting_kwargs_fail_fast():
    """Contradictory regions= / levels= / branching= / region_of=
    combinations raise with a clear message instead of silently building
    some other plane (mirrors the flat plane's region_of contradiction
    check)."""
    rg, assign = region_tree(2, 2, 3, seed=0)  # 4 leaves, n=12
    with pytest.raises(ValueError, match="levels=0"):
        ControlPlane(rg, levels=0)
    with pytest.raises(ValueError, match="not a perfect levels=2 power"):
        ControlPlane(rg, levels=2, regions=7, **PYM)
    with pytest.raises(ValueError, match="contradicts levels=2 x branching=3"):
        ControlPlane(rg, levels=2, branching=3, regions=4, **PYM)
    with pytest.raises(ValueError, match="requires a hierarchical plane"):
        ControlPlane(rg, branching=3, **PYM)
    with pytest.raises(ValueError, match="contradicts region_of"):
        ControlPlane(rg, levels=2, region_of=assign, regions=9, **PYM)
    with pytest.raises(ValueError, match="contradicts levels=2 x branching=3"):
        ControlPlane(rg, levels=2, branching=3, region_of=assign, **PYM)
    with pytest.raises(ValueError, match="branching=5 contradicts 3 leaf"):
        resolve_nesting(1, 5, 3)
    # direct construction of the flat plane rejects the nesting kwargs too
    with pytest.raises(ValueError, match="flat"):
        RegionalControlPlane(rg, regions=2, levels=2, **PYM)
    with pytest.raises(ValueError, match="hierarchical"):
        RegionalControlPlane(rg, regions=2, branching=2, **PYM)


# ---------------------------------------------------------------------------
# levels=1 bit-identity (the flat plane falls out as the special case)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_levels1_hierarchy_bit_identical_to_flat(seed):
    """HierarchicalControlPlane(levels=1) replays the exact flat
    RegionalControlPlane behavior — same rids, same tickets, same residual
    arrays bit for bit, same ledger — step by step under a fuzzed op
    sequence (the R=1-vs-centralized argument, one level up: one child
    under the identity view, pure delegation, seeds aligned)."""
    rg = waxman(14, seed=4)
    kw = dict(micro_batch=6, max_attempts=3, seed=seed,
              policy=FairSharePolicy(slack=0.4), **PYM)
    flat = RegionalControlPlane(rg, regions=3, **kw)
    hier = HierarchicalControlPlane(rg, levels=1, regions=3, **kw)
    assert hier.B == 1 and hier.children[0].R == 3
    for cp in (flat, hier):
        cp.register_tenant("a", weight=3.0)
        cp.register_tenant("b", weight=1.0)
    rng = np.random.default_rng(seed)
    failed: list[int] = []
    for step in range(60):
        op = rng.choice(
            ["submit", "pump", "release", "fail", "restore", "defrag"],
            p=[0.35, 0.28, 0.15, 0.08, 0.07, 0.07],
        )
        if op == "submit":
            df = random_dataflow(rg, 4, seed=3000 * seed + step,
                                 creq_range=(0.05, 0.3),
                                 breq_range=(0.5, 3.0))
            t = str(rng.choice(["a", "b"]))
            k = int(rng.integers(0, 3))
            assert flat.submit(t, df, klass=k) == hier.submit(t, df, klass=k)
        elif op == "pump":
            r = int(rng.integers(1, 3))
            hf = [(getattr(t, "tid", None), getattr(t, "rid", None))
                  for t in flat.pump(rounds=r)]
            hh = [(getattr(t, "tid", None), getattr(t, "rid", None))
                  for t in hier.pump(rounds=r)]
            assert hf == hh
        elif op == "release":
            ids = flat.active_ids()
            assert ids == hier.active_ids()
            if ids:
                rid = int(rng.choice(ids))
                flat.release(rid)
                hier.release(rid)
        elif op == "fail" and len(failed) < 3:
            v = int(rng.integers(0, rg.n))
            if v not in failed:
                a1, q1 = flat.fail_node(v)
                a2, q2 = hier.fail_node(v)
                assert [t.tid for t in a1] == [t.tid for t in a2]
                assert [t.tid for t in q1] == [t.tid for t in q2]
                failed.append(v)
        elif op == "restore" and failed:
            v = failed.pop(int(rng.integers(0, len(failed))))
            flat.restore_node(v)
            hier.restore_node(v)
        elif op == "defrag":
            rf = flat.defrag()
            rh = hier.defrag()
            assert [(r.committed, r.repacked, r.moved) for r in rf] == \
                [(r.committed, r.repacked, r.moved) for r in rh]
        # -- bit-for-bit state equality, every step
        inner = hier.children[0]
        assert flat.active_ids() == hier.active_ids()
        for r in range(flat.R):
            np.testing.assert_array_equal(
                flat.regions[r].placer.cap, inner.regions[r].placer.cap)
            np.testing.assert_array_equal(
                flat.regions[r].placer.bw, inner.regions[r].placer.bw)
            assert sorted(flat.regions[r].placer.tickets) == \
                sorted(inner.regions[r].placer.tickets)
        assert flat.cut_residual == inner.cut_residual
        assert flat.conservation() == hier.conservation()
        flat.check_invariants()
        hier.check_invariants()
    # the enclosing level spent zero coordination messages at levels=1
    assert hier.bus.messages_sent == 0 and hier._twopc_msgs == 0
    assert hier.engine_stats().twopc_messages == \
        flat.engine_stats().twopc_messages


# ---------------------------------------------------------------------------
# nested-plane fuzz (conservation at every level)
# ---------------------------------------------------------------------------


def _fuzz_hierarchy(cp, rg, seed, steps=60):
    """Adversarial interleaving of every public operation; every step
    checks each level's ledger, cut conservation, spanning-handle
    integrity, and the cross-level write-through reassembly."""
    rng = np.random.default_rng(seed)
    failed_nodes: list[int] = []
    failed_cuts: list[tuple[int, int]] = []
    cuts = sorted(cp.cut_base)
    for step in range(steps):
        op = rng.choice(
            ["submit", "pump", "release", "fail_node", "restore_node",
             "partition", "heal", "defrag"],
            p=[0.30, 0.25, 0.13, 0.08, 0.08, 0.05, 0.05, 0.06],
        )
        if op == "submit":
            df = random_dataflow(rg, 4, seed=1000 * seed + step,
                                 creq_range=(0.05, 0.3),
                                 breq_range=(0.5, 3.0))
            cp.submit(str(rng.choice(["a", "b", "c"])), df,
                      klass=int(rng.integers(0, 3)))
        elif op == "pump":
            cp.pump(rounds=int(rng.integers(1, 3)))
        elif op == "release":
            ids = cp.active_ids()
            if ids:
                cp.release(int(rng.choice(ids)))
        elif op == "fail_node" and len(failed_nodes) < 3:
            v = int(rng.integers(0, rg.n))
            if v not in failed_nodes:
                cp.fail_node(v)
                failed_nodes.append(v)
        elif op == "restore_node" and failed_nodes:
            cp.restore_node(failed_nodes.pop(
                int(rng.integers(0, len(failed_nodes)))))
        elif op == "partition" and cuts and len(failed_cuts) < 2:
            e = cuts[int(rng.integers(0, len(cuts)))]
            if e not in failed_cuts:
                cp.fail_link(*e)
                failed_cuts.append(e)
        elif op == "heal" and failed_cuts:
            cp.restore_link(*failed_cuts.pop(
                int(rng.integers(0, len(failed_cuts)))))
        elif op == "defrag":
            for res in cp.defrag():
                assert res.objective_after >= res.objective_before
        cp.check_invariants()
    cp.flush()
    cp.check_invariants()
    led = cp.conservation()
    assert led["ok"] and led["in_flight"] == 0
    return led


@pytest.mark.parametrize("seed", [0, 1])
def test_fuzz_hierarchy_conservation(seed):
    rg, assign = region_tree(2, 3, 4, seed=3)  # 9 leaves, n=36
    cp = HierarchicalControlPlane(
        rg, levels=2, region_of=assign, micro_batch=6, max_attempts=3,
        seed=seed, policy=FairSharePolicy(slack=0.4), **PYM,
    )
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    cp.register_tenant("c", weight=2.0, budget=1.5)
    led = _fuzz_hierarchy(cp, rg, seed)
    assert led["submitted"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("seed", [2, 3])
def test_fuzz_hierarchy_conservation_3level(seed):
    rg, assign = region_tree(3, 2, 3, seed=4)  # 8 leaves, n=24
    cp = HierarchicalControlPlane(
        rg, levels=3, region_of=assign, micro_batch=6, max_attempts=3,
        seed=seed, policy=FairSharePolicy(slack=0.4), **PYM,
    )
    for t, w in (("a", 3.0), ("b", 1.0), ("c", 2.0)):
        cp.register_tenant(t, weight=w)
    led = _fuzz_hierarchy(cp, rg, seed, steps=100)
    assert led["submitted"] > 0


# ---------------------------------------------------------------------------
# recursive spanning decomposition
# ---------------------------------------------------------------------------


def _tree_plane(levels=2, b=2, k=4, seed=0, **kw):
    rg, assign = region_tree(levels, b, k, seed=seed)
    cp = HierarchicalControlPlane(
        rg, levels=levels, region_of=assign, micro_batch=8,
        max_attempts=4, seed=seed, **PYM, **kw,
    )
    cp.register_tenant("a")
    return rg, assign, cp


def _cross_tree_df(rg, creq=0.1, breq=0.5):
    """A dataflow pinned from the first to the last node — guaranteed to
    cross the top-level cut of any depth-first region tree."""
    return DataflowPath.make(
        [0.0, creq, creq, 0.0], [breq, breq, breq], 0, rg.n - 1)


def test_cross_group_spanning_splits_at_every_level():
    """A dataflow crossing the top-level cut is split there, and each
    segment is admitted by the child plane — which may split again at its
    own cuts: the parts of the top span are broker-held spans inside the
    children, recursively well-formed at every level."""
    rg, assign, cp = _tree_plane(levels=2, b=2, k=4)
    rid = cp.submit("a", _cross_tree_df(rg))
    (st,) = cp.pump()
    assert st.rid == rid and len(st.parts) == 2 and len(st.cuts) == 1
    assert cp.span_stats["admitted"] == 1
    # each part is a live broker-held reservation inside its child
    for part in st.parts:
        child = cp.children[part.region]
        assert part.tid in child._broker_held
        assert part.tid in child._span_active
    cp.check_invariants()
    # src and dst leaves are in different groups AND different leaf
    # regions inside them, so at least one child had to split again
    # (its broker-held span has its own cut) or place via its gateway
    assert cp.group_of[0] != cp.group_of[rg.n - 1]
    cp.release(rid)
    cp.check_invariants()
    led = cp.conservation()
    assert led["active"] == 0 and led["ok"]
    # the teardown released every child holding too
    for child in cp.children:
        assert not child._broker_held


def test_gateway_failure_displaces_top_span_and_heals():
    rg, assign, cp = _tree_plane(levels=2, b=2, k=4)
    rid = cp.submit("a", _cross_tree_df(rg))
    (st,) = cp.pump()
    (u, v) = st.cuts[0]
    alive, requeued = cp.fail_node(u)
    assert st in requeued
    assert rid not in cp._span_active
    for child in cp.children:
        assert not child._broker_held  # sibling reservations torn down
    cp.check_invariants()
    assert cp.conservation()["ok"]
    cp.restore_node(u)
    got = cp.pump(rounds=4)
    assert any(getattr(t, "rid", None) == rid for t in got)
    cp.check_invariants()


def test_cut_link_failure_displaces_and_requeues():
    rg, assign, cp = _tree_plane(levels=2, b=2, k=4)
    rid = cp.submit("a", _cross_tree_df(rg))
    (st,) = cp.pump()
    alive, requeued = cp.fail_link(*st.cuts[0])
    assert st in requeued and rid not in cp._span_active
    cp.check_invariants()
    # full bandwidth back on the ledger for the failed (but intact) link
    cp.restore_link(*st.cuts[0])
    assert cp.cut_residual[st.cuts[0]] == cp.cut_base[st.cuts[0]]
    got = cp.pump(rounds=4)
    assert any(getattr(t, "rid", None) == rid for t in got)
    cp.check_invariants()


def test_child_displacement_chains_up_through_broker_hook():
    """Churn INSIDE a child that kills a top-level segment must tear the
    whole composite down at the top (on_broker_displace), not leak the
    sibling reservations."""
    rg, assign, cp = _tree_plane(levels=2, b=2, k=4)
    rid = cp.submit("a", _cross_tree_df(rg))
    (st,) = cp.pump()
    # fail a node the span actually uses strictly inside one child (not a
    # top-level gateway of this span's cut)
    gateways = {v for e in st.cuts for v in e}
    used = [
        v for v in range(rg.n)
        if v not in gateways and cp._span_uses_node(st, v)
    ]
    assert used, "span places no interior node; pick a bigger instance"
    cp.fail_node(used[0])
    assert rid not in cp._span_active
    for child in cp.children:
        assert not child._broker_held
    cp.check_invariants()
    assert cp.conservation()["ok"]


def test_release_rejects_parent_held_rid_at_child_level():
    rg, assign, cp = _tree_plane(levels=2, b=2, k=4)
    cp.submit("a", _cross_tree_df(rg))
    (st,) = cp.pump()
    part = st.parts[0]
    with pytest.raises(KeyError, match="broker"):
        cp.children[part.region].release(part.tid)


def test_flat_broker_admit_release_roundtrip():
    """The flat plane's parent-broker interface on its own: in-region and
    cross-region broker reservations are first-class ledger entries,
    invisible to active_ids, idempotently releasable, and protected from
    plain release()."""
    rg, assign = region_tree(1, 3, 4, seed=5)  # flat: 3 meshed regions
    cp = RegionalControlPlane(rg, region_of=assign, seed=0, **PYM)
    cp.register_tenant("a")
    # in-region reservation
    r1 = cp.broker_admit("a", DataflowPath.make([0.0, 0.1], [0.5], 0, 1))
    # cross-region reservation (spans the plane's own cut)
    r2 = cp.broker_admit("a", _cross_tree_df(rg))
    assert r1 is not None and r2 is not None
    assert cp.active_ids() == []  # parent-held: not caller-visible
    assert cp.conservation()["ok"] and cp.conservation()["active"] == 2
    assert cp.broker_uses_node(r1, 0)
    with pytest.raises(KeyError, match="broker"):
        cp.release(r1)
    cp.check_invariants()
    cp.broker_release(r1)
    cp.broker_release(r1)  # idempotent
    cp.broker_release(r2)
    led = cp.conservation()
    assert led["active"] == 0 and led["released"] == 2 and led["ok"]
    cp.check_invariants()


# ---------------------------------------------------------------------------
# tree-structured gossip / resident state
# ---------------------------------------------------------------------------


def test_tree_gossip_message_and_record_budget():
    """Each level's bus carries only that level's siblings: messages per
    round are O(branching * fanout) per component, and every message holds
    at most ``branching`` aggregated records — never one record per leaf
    region, let alone per node."""
    rg, assign = region_tree(2, 4, 3, seed=6)  # 16 leaves, n=48
    cp = HierarchicalControlPlane(
        rg, levels=2, region_of=assign, fanout=2, seed=0, **PYM)
    cp.register_tenant("a")
    rng = np.random.default_rng(0)
    for i in range(24):
        cp.submit("a", random_dataflow(rg, 3, seed=i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    cp.pump(rounds=6)
    top = cp.bus.gossip_stats()
    assert top["messages_per_round"] <= cp.B * cp.bus.fanout
    assert top["records_per_message"] <= cp.B
    for child in cp.children:
        st = child.bus.gossip_stats()
        assert st["messages_per_round"] <= child.R * child.bus.fanout
        assert st["records_per_message"] <= child.R
    # flat plane over the same 16 leaves: every record still bounded by R,
    # but R is the GLOBAL region count — 4x the hierarchy's branching
    flat = RegionalControlPlane(rg, region_of=assign, fanout=2, seed=0,
                                **PYM)
    flat.register_tenant("a")
    for i in range(24):
        flat.submit("a", random_dataflow(rg, 3, seed=i,
                                         creq_range=(0.05, 0.2),
                                         breq_range=(0.5, 2.0)))
    flat.pump(rounds=6)
    fst = flat.bus.gossip_stats()
    assert fst["records_per_message"] > cp.B  # the flat view is R-sized
    cp.check_invariants()


def test_gossip_payload_accounting():
    """records_sent / payload_sent count what the wire would carry:
    records x (3 scalars + committed + queued entries)."""
    from repro.service import GossipBus

    bus = GossipBus(3, fanout=2, seed=0)
    bus.publish(0, {"a": 1.0, "b": 2.0}, {"a": 0.5}, 7.0)
    bus.publish(1, {"a": 0.0}, {}, 3.0)
    sent = bus.tick()
    assert sent == 6  # R * fanout
    # regions 0 and 1 each pushed their 1-record view to 2 peers; region 2
    # pushed an empty view to 2 peers
    assert bus.records_sent == 4
    # region 0's record: 3 + 2 committed + 1 queued = 6; region 1's:
    # 3 + 1 + 0 = 4; each carried twice
    assert bus.payload_sent == 2 * 6 + 2 * 4
    st = bus.gossip_stats()
    assert st["payload_per_round"] == bus.payload_sent
    assert st["records_per_message"] == pytest.approx(4 / 6)


def test_resident_state_hierarchy_strictly_below_flat():
    """The headline scaling claim at test size: over the same 16-leaf
    tree, the 2-level plane's largest component (solve size + peer/id
    tables) is strictly smaller than the flat plane's — the flat broker
    must hold every gateway id and every region as a peer."""
    rg, assign = region_tree(2, 4, 3, seed=7)  # 16 leaves, n=48
    flat = RegionalControlPlane(rg, region_of=assign, seed=0, **PYM)
    hier = HierarchicalControlPlane(
        rg, levels=2, region_of=assign, seed=0, **PYM)
    f = flat.resident_state_report()
    h = hier.resident_state_report()
    assert h["max_component_state"] < f["max_component_state"]
    # and no hierarchy component holds an id table sized like the flat
    # broker's global boundary
    flat_broker = next(
        c for c in f["components"] if c["component"] == "broker")
    for c in h["components"]:
        assert c.get("id_table", 0) < flat_broker["id_table"]


def test_coordination_report_nests():
    rg, assign, cp = _tree_plane(levels=2, b=2, k=3)
    cp.submit("a", _cross_tree_df(rg))
    cp.pump(rounds=2)
    rep = cp.coordination_report()
    assert rep["levels"] == 2 and rep["branching"] == 2
    assert rep["leaf_regions"] == 4
    assert len(rep["children"]) == 2
    assert rep["resident"]["max_component_state"] > 0
    assert rep["gossip"]["n_regions"] == 2
    fair = cp.fairness_report()
    assert "coordination" in fair


# ---------------------------------------------------------------------------
# congestion-aware k-chain routing through the tree
# ---------------------------------------------------------------------------


def test_routing_knobs_propagate_down_the_tree():
    """chain_k / congestion_weight / max_cum_attempts reach every nested
    plane (and never leak into the solver config)."""
    rg, assign = region_tree(2, 2, 3, seed=0)
    cp = ControlPlane(rg, levels=2, region_of=assign, chain_k=3,
                      congestion_weight=0.5, max_cum_attempts=7, **PYM)
    planes = [cp]
    while planes:
        p = planes.pop()
        assert p.chain_k == 3
        assert p.congestion_weight == 0.5
        assert p.max_cum_attempts == 7
        planes.extend(getattr(p, "children", []))
    cp.register_tenant("a")
    cp.submit("a", _cross_tree_df(rg))
    cp.pump()
    cp.check_invariants()


def test_top_level_k_chains_exist_on_sibling_mesh():
    """Top-level siblings are all-to-all, so Yen finds a 2-hop bypass
    behind every direct chain — the racer has real alternatives at the
    top of the tree too."""
    rg, assign, cp = _tree_plane(levels=2, b=3, k=3, chain_k=2)
    chains = cp._region_chains(0, 1, {})
    assert chains[0] == [0, 1] and len(chains) == 2
    assert chains[1] == [0, 2, 1]


def test_congestion_published_at_every_level():
    """Each level's bus carries occupancy estimates for its own gateway
    nodes (folded recursively out of the children via node_occupancy)."""
    rg, assign, cp = _tree_plane(levels=2, b=2, k=4, fanout=1)
    cp.submit("a", _cross_tree_df(rg))
    cp.pump()
    view = cp.bus.congestion_view(0)
    own = cp._gateways_of.get(0, ())
    assert own and all(u in view for u in own)
    assert all(0.0 <= view[u] <= 1.0 for u in view)
    for g in range(cp.B):
        for u in cp._gateways_of.get(g, ()):
            assert 0.0 <= cp.node_occupancy(int(u)) <= 1.0
    # the child planes publish their own (local-id) gateway estimates too
    child = cp.children[0]
    crec = child.bus.views[0].get(0)
    assert crec is not None and isinstance(crec.congestion, dict)


def test_hierarchy_cut_fail_restore_keeps_ledger_coherent():
    """Top-level cut fail/restore under a standing cross-group span: the
    healed cut reappears with its full residual, double fail/restore is
    idempotent, and the displaced request is readmitted."""
    rg, assign, cp = _tree_plane(levels=2, b=2, k=4)
    rid = cp.submit("a", _cross_tree_df(rg))
    (st,) = cp.pump()
    e = st.cuts[0]
    cp.fail_link(*e)
    cp.fail_link(*e)  # idempotent
    cp.check_invariants()
    assert cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
    assert all(-1e-6 <= cp.cut_residual[c] <= cp.cut_base[c] + 1e-6
               for c in cp.cut_base)
    cp.restore_link(*e)
    cp.restore_link(*e)  # idempotent
    assert cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
    got = cp.pump(rounds=4)
    assert any(getattr(t, "rid", None) == rid for t in got)
    cp.check_invariants()


@pytest.mark.parametrize("seed", [0, 1])
def test_fuzz_hierarchy_k_chain_conservation(seed):
    """The full fuzz suite with the k-chain racer live at every level of
    a 3-sibling tree (real bypass chains exist top-level)."""
    rg, assign = region_tree(2, 3, 4, seed=3)
    cp = HierarchicalControlPlane(
        rg, levels=2, region_of=assign, micro_batch=6, max_attempts=3,
        seed=seed, chain_k=3, policy=FairSharePolicy(slack=0.4), **PYM,
    )
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    cp.register_tenant("c", weight=2.0, budget=1.5)
    led = _fuzz_hierarchy(cp, rg, seed)
    assert led["submitted"] > 0
